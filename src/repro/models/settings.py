"""Global model-tracing settings (read at trace time).

REMAT: rematerialize each scanned layer's activations in the backward pass
(activation checkpointing). Enabled by the train-step builder for the
production shapes; left off for small CPU unit tests.

ACTIVATION_MESH: when set (by the dry-run / launcher), models pin activation
shardings at layer boundaries via with_sharding_constraint. Without these
pins GSPMD may align activations to the weights' layout instead — replicating
the batch across the data axis and multiplying compute by the axis size
(observed on the 16x16 mesh; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

REMAT = False
ACTIVATION_MESH: dict | None = None    # {"sizes": {axis: size, ...}}

# Perf experiment (EXPERIMENTS.md §Perf cell 2): when KV-group counts don't
# divide the model axis, pad the GQA group dim inside attention so each rank
# owns whole groups (62.5% util for phi3 vs 6.25% replicated). Off = paper-
# faithful baseline.
ATTN_GROUP_PAD = False


def attn_group_pad_target(n_kv: int, n_heads: int = 0) -> int | None:
    """Padded KV-group count, or None when padding is off/unnecessary.

    Padding only pays when the Q-head axis itself cannot be sharded
    (n_heads % model != 0, e.g. phi3's 40): if Q-heads already divide, the
    attention flops are sharded and padding the groups would only add pad
    waste (observed on grok-1: kv=8, H=48)."""
    if not ATTN_GROUP_PAD or ACTIVATION_MESH is None:
        return None
    model = ACTIVATION_MESH["sizes"].get("model", 1)
    if model <= 1 or n_kv % model == 0:
        return None
    if n_heads and n_heads % model == 0:
        return None
    if n_kv > model:
        return ((n_kv + model - 1) // model) * model
    return model


def set_activation_mesh(mesh) -> None:
    global ACTIVATION_MESH
    if mesh is None:
        ACTIVATION_MESH = None
    else:
        ACTIVATION_MESH = {"sizes": {k: int(v) for k, v in mesh.shape.items()}}


@contextlib.contextmanager
def activation_mesh(mesh):
    global ACTIVATION_MESH
    old = ACTIVATION_MESH
    set_activation_mesh(mesh)
    try:
        yield
    finally:
        ACTIVATION_MESH = old


def _batch_axes(sizes):
    return tuple(a for a in ("pod", "data") if a in sizes)


def shard_activation(x, model_dim_axis: int | None = None):
    """Pin (B, S, ...) activations: batch over ('pod','data'), falling back
    to sequence sharding for batch-1 long-context shapes."""
    if ACTIVATION_MESH is None or x.ndim < 2:
        return x
    sizes = ACTIVATION_MESH["sizes"]
    ba = _batch_axes(sizes)
    bsz = int(np.prod([sizes[a] for a in ba])) if ba else 1
    spec = [None] * x.ndim
    if ba and x.shape[0] % bsz == 0 and x.shape[0] > 1:
        spec[0] = ba if len(ba) > 1 else ba[0]
    elif "data" in sizes and x.shape[1] % sizes["data"] == 0 and x.shape[1] > 1:
        spec[1] = "data"
    if model_dim_axis is not None and "model" in sizes \
            and x.shape[model_dim_axis] % sizes["model"] == 0:
        spec[model_dim_axis] = "model"
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except RuntimeError:   # no mesh context (pure-numeric tests)
        return x


def shard_logits(x):
    """(B, S, V) logits: batch over data axes, vocab over 'model'."""
    if ACTIVATION_MESH is None:
        return x
    return shard_activation(x, model_dim_axis=x.ndim - 1)


def pin(x, names):
    """Explicit per-dim pin: names entries are None | 'batch' | 'model' |
    'data'. Dims that don't divide their axis are left unsharded."""
    if ACTIVATION_MESH is None:
        return x
    sizes = ACTIVATION_MESH["sizes"]
    spec = []
    for dim, name in zip(x.shape, names):
        if name == "batch":
            ba = _batch_axes(sizes)
            bsz = int(np.prod([sizes[a] for a in ba])) if ba else 1
            ok = ba and dim % bsz == 0 and dim > 1
            spec.append((ba if len(ba) > 1 else ba[0]) if ok else None)
        elif name in ("model", "data"):
            ok = name in sizes and dim % sizes[name] == 0 and dim > 1
            spec.append(name if ok else None)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except RuntimeError:
        return x


def maybe_remat(fn):
    if REMAT:
        return jax.checkpoint(fn)
    return fn


@contextlib.contextmanager
def remat(enabled: bool = True):
    global REMAT
    old = REMAT
    REMAT = enabled
    try:
        yield
    finally:
        REMAT = old
