"""Architecture registry: --arch <id> -> (config, model builder)."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig
from repro.models.transformer import DecoderLM, EncDecLM
from repro.models.xlstm import XLSTM
from repro.models.zamba import Zamba

ARCH_IDS = [
    "grok-1-314b", "granite-moe-3b-a800m", "deepseek-67b", "phi3-medium-14b",
    "nemotron-4-340b", "yi-9b", "xlstm-350m", "paligemma-3b",
    "seamless-m4t-large-v2", "zamba2-7b",
]

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
               for a in ARCH_IDS}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch '{name}'; available: {ARCH_IDS}")
    mod = importlib.import_module(_MODULE_FOR[name])
    return mod.ARCH


def build_model(arch: ArchConfig):
    if arch.family == "audio" and arch.n_enc_layers:
        return EncDecLM(arch)
    if arch.family == "ssm":
        return XLSTM(arch)
    if arch.family == "hybrid":
        return Zamba(arch)
    return DecoderLM(arch)   # dense | moe | vlm


def build_by_name(name: str, reduced: bool = False):
    arch = get_arch(name)
    if reduced:
        arch = arch.reduced()
    return arch, build_model(arch)
