"""Zamba2-7B: Mamba2 backbone + shared full-attention blocks (hybrid).

81 Mamba2 blocks; after every ``attn_every`` blocks one of two *weight-shared*
transformer blocks is applied (alternating), following Zamba2's
shared-attention design. Mamba2's SSD recurrence is the same chunked GLA
substrate as mLSTM (scalar per-head decay a_t = exp(-dt * A)).

Decode state = per-block (conv window, GLA state) + one KV cache per shared
attention *site* (weights shared, caches not) — sub-quadratic in compute, so
this arch runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models.layers import DTYPE, _init
from repro.models.ssm import gla_chunked, gla_step
from repro.models.transformer import layer_init, layer_apply, layer_decode
from repro.models.settings import maybe_remat, shard_activation, shard_logits

CONV_K = 4


def _dims(arch: ArchConfig):
    d_inner = 2 * arch.d_model
    heads = d_inner // arch.ssm_head_dim
    return d_inner, heads, arch.ssm_state


def mamba_init(key, arch: ArchConfig):
    D = arch.d_model
    d_inner, H, N = _dims(arch)
    ks = jax.random.split(key, 8)
    return {
        "ln": L.rmsnorm_init(D),
        "w_z": _init(ks[0], (D, d_inner), D),
        "w_x": _init(ks[1], (D, d_inner), D),
        "w_B": _init(ks[2], (D, N), D),
        "w_C": _init(ks[3], (D, N), D),
        "w_dt": (jax.random.normal(ks[4], (D, H)) * 0.02).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = exp(A_log) > 0
        "conv_w": (jax.random.normal(ks[5], (CONV_K, d_inner)) *
                   CONV_K ** -0.5).astype(DTYPE),
        "D_skip": jnp.ones((H,), jnp.float32),
        "out_norm": L.rmsnorm_init(d_inner),
        "w_out": _init(ks[6], (d_inner, D), d_inner),
    }


def _causal_conv(x, w):
    """Depthwise causal conv, x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out


def _mamba_core(p, arch, xn):
    d_inner, H, N = _dims(arch)
    z = jnp.einsum("bsd,di->bsi", xn, p["w_z"])
    xs = jnp.einsum("bsd,di->bsi", xn, p["w_x"])
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"]))
    Bv = jnp.einsum("bsd,dn->bsn", xn, p["w_B"])
    Cv = jnp.einsum("bsd,dn->bsn", xn, p["w_C"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", xn.astype(jnp.float32),
                                    p["w_dt"]) + p["dt_bias"])
    log_a = -dt * jnp.exp(p["A_log"])                       # (B,S,H)
    B_, S, _ = xs.shape
    v = xs.reshape(B_, S, H, arch.ssm_head_dim)
    k = jnp.broadcast_to(Bv[:, :, None, :], (B_, S, H, N)) * \
        dt[..., None].astype(Bv.dtype)
    q = jnp.broadcast_to(Cv[:, :, None, :], (B_, S, H, N))
    return z, v, k, q, log_a


def _mamba_out(p, arch, x, y, v, z):
    d_inner, H, _ = _dims(arch)
    B_, S = y.shape[0], y.shape[1]
    y = y + v * p["D_skip"][None, None, :, None].astype(v.dtype)
    y = y.reshape(B_, S, d_inner)
    y = L.rmsnorm(p["out_norm"], y * jax.nn.silu(z), arch.norm_eps)
    return x + jnp.einsum("bsi,id->bsd", y, p["w_out"])


def mamba_apply(p, arch: ArchConfig, x, chunk=256):
    x = shard_activation(x)
    xn = L.rmsnorm(p["ln"], x, arch.norm_eps)
    z, v, k, q, log_a = _mamba_core(p, arch, xn)
    y, _, _ = gla_chunked(q, k, v, log_a, chunk=min(chunk, x.shape[1]),
                          normalize=False)
    return _mamba_out(p, arch, x, y, v, z)


def mamba_decode(p, arch: ArchConfig, x, conv_state, gla_state):
    """x: (B,1,D); conv_state: (B,K-1,d_inner); gla_state: (B,H,N,hd)."""
    d_inner, H, N = _dims(arch)
    xn = L.rmsnorm(p["ln"], x, arch.norm_eps)
    z = jnp.einsum("bsd,di->bsi", xn, p["w_z"])
    xs = jnp.einsum("bsd,di->bsi", xn, p["w_x"])
    window = jnp.concatenate([conv_state, xs], axis=1)       # (B,K,d_inner)
    new_conv = window[:, 1:]
    xs = jax.nn.silu(jnp.einsum("bki,ki->bi", window, p["conv_w"]))[:, None]
    Bv = jnp.einsum("bsd,dn->bsn", xn, p["w_B"])
    Cv = jnp.einsum("bsd,dn->bsn", xn, p["w_C"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", xn.astype(jnp.float32),
                                    p["w_dt"]) + p["dt_bias"])
    log_a = -dt * jnp.exp(p["A_log"])
    B_ = x.shape[0]
    v = xs.reshape(B_, 1, H, arch.ssm_head_dim)
    k = jnp.broadcast_to(Bv[:, :, None, :], (B_, 1, H, N)) * \
        dt[..., None].astype(Bv.dtype)
    q = jnp.broadcast_to(Cv[:, :, None, :], (B_, 1, H, N))
    y, gla_state, _ = gla_step(gla_state, jnp.zeros_like(gla_state[..., 0]),
                               q[:, 0], k[:, 0], v[:, 0], log_a[:, 0],
                               normalize=False)
    y = y[:, None]
    x = _mamba_out(p, arch, x, y, v, z)
    return x, new_conv, gla_state


# ------------------------------------------------------------------ model

class Zamba:
    N_SHARED = 2   # two alternating shared transformer blocks

    def __init__(self, arch: ArchConfig):
        self.arch = arch
        self.n_groups = arch.n_layers // arch.attn_every  # shared-attn sites

    def init(self, key):
        arch = self.arch
        k1, k2, k3 = jax.random.split(key, 3)
        keys_m = jax.random.split(k2, arch.n_layers)
        keys_s = jax.random.split(k3, self.N_SHARED)
        return {
            "embed": L.embedding_init(k1, arch.vocab, arch.d_model),
            "mamba": jax.vmap(lambda k: mamba_init(k, arch))(keys_m),
            "shared": jax.vmap(lambda k: layer_init(k, arch))(keys_s),
            "final_norm": L.rmsnorm_init(arch.d_model),
        }

    def _group(self, params, g):
        ae = self.arch.attn_every
        return jax.tree_util.tree_map(lambda a: a[g * ae:(g + 1) * ae],
                                      params["mamba"])

    def _hidden(self, params, tokens, q_chunk=1024, k_chunk=1024):
        arch = self.arch
        x = shard_activation(L.embed(params["embed"], tokens))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def m_body(x, lp):
            return mamba_apply(lp, arch, x), None

        m_body = maybe_remat(m_body)
        for g in range(self.n_groups):
            x, _ = lax.scan(m_body, x, self._group(params, g))
            sp = jax.tree_util.tree_map(lambda a: a[g % self.N_SHARED],
                                        params["shared"])
            x = layer_apply(sp, arch, x, positions, q_chunk=q_chunk,
                            k_chunk=k_chunk)
        rem = arch.n_layers - self.n_groups * arch.attn_every
        if rem:
            tail = jax.tree_util.tree_map(
                lambda a: a[self.n_groups * arch.attn_every:], params["mamba"])
            x, _ = lax.scan(m_body, x, tail)
        return L.rmsnorm(params["final_norm"], x, arch.norm_eps)

    def train_loss(self, params, batch):
        x = self._hidden(params, batch["tokens"])
        logits = shard_logits(L.unembed(params["embed"], x))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                                   axis=-1)[..., 0]
        mask = (batch["targets"] >= 0).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
        return loss, {"loss": loss}

    def prefill_step(self, params, batch):
        x = self._hidden(params, batch["tokens"])
        return L.unembed(params["embed"], x[:, -1:])[:, 0]

    def init_cache(self, batch: int, max_len: int):
        arch = self.arch
        d_inner, H, N = _dims(arch)
        hd = arch.resolved_head_dim
        nL, nG = arch.n_layers, self.n_groups
        return {
            "conv": jnp.zeros((nL, batch, CONV_K - 1, d_inner), DTYPE),
            "gla": jnp.zeros((nL, batch, H, N, arch.ssm_head_dim), jnp.float32),
            "k": jnp.zeros((nG, batch, max_len, arch.n_kv_heads, hd), DTYPE),
            "v": jnp.zeros((nG, batch, max_len, arch.n_kv_heads, hd), DTYPE),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def serve_step(self, params, cache, tokens):
        arch = self.arch
        ae = arch.attn_every
        x = L.embed(params["embed"], tokens[:, None])
        pos = cache["pos"]

        def m_body(x, scanned):
            lp, conv, gla = scanned
            x, nconv, ngla = mamba_decode(lp, arch, x, conv, gla)
            return x, (nconv, ngla)

        convs, glas, ks, vs = [], [], [], []
        for g in range(self.n_groups):
            sl = lambda a: a[g * ae:(g + 1) * ae]
            x, (nc, ng) = lax.scan(m_body, x, (self._group(params, g),
                                               sl(cache["conv"]),
                                               sl(cache["gla"])))
            convs.append(nc)
            glas.append(ng)
            sp = jax.tree_util.tree_map(lambda a: a[g % self.N_SHARED],
                                        params["shared"])
            x, site = layer_decode(sp, arch, x,
                                   {"k": cache["k"][g], "v": cache["v"][g]}, pos)
            ks.append(site["k"])
            vs.append(site["v"])
        rem = arch.n_layers - self.n_groups * ae
        if rem:
            sl = lambda a: a[self.n_groups * ae:]
            x, (nc, ng) = lax.scan(m_body, x, (
                jax.tree_util.tree_map(sl, params["mamba"]),
                sl(cache["conv"]), sl(cache["gla"])))
            convs.append(nc)
            glas.append(ng)
        x = L.rmsnorm(params["final_norm"], x, arch.norm_eps)
        logits = L.unembed(params["embed"], x)[:, 0]
        return logits, {"conv": jnp.concatenate(convs),
                        "gla": jnp.concatenate(glas),
                        "k": jnp.stack(ks), "v": jnp.stack(vs),
                        "pos": pos + 1}

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
