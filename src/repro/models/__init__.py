from repro.models.registry import ARCH_IDS, get_arch, build_model, build_by_name
