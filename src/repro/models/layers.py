"""Common transformer layers in pure JAX: RMSNorm, RoPE, GQA attention
(training, prefill, and cached decode), chunked flash-style attention for long
sequences, and the MLP variants used across the assigned architectures.

All params are plain dict pytrees; init_* functions take explicit dims so the
whole model can be constructed under jax.eval_shape for the dry-run. Compute
dtype is bf16 with fp32 softmax/normalization accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

DTYPE = jnp.bfloat16


def _init(key, shape, fan_in, dtype=DTYPE):
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


# ------------------------------------------------------------------ norms

def rmsnorm_init(dim):
    return {"scale": jnp.ones((dim,), DTYPE)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


# ------------------------------------------------------------------- rope

def rope(x, positions, theta=1e4):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention

def attention_init(key, d_model, n_heads, n_kv, head_dim):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _init(kq, (d_model, n_heads, head_dim), d_model),
        "wk": _init(kk, (d_model, n_kv, head_dim), d_model),
        "wv": _init(kv, (d_model, n_kv, head_dim), d_model),
        "wo": _init(ko, (n_heads, head_dim, d_model), n_heads * head_dim),
    }


def _gqa_scores_softmax_v(q, k, v, mask_bias):
    """q: (B,Sq,H,hd) k/v: (B,Sk,KV,hd). Full (non-chunked) path."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd)
    scores = scores + mask_bias  # (B,KV,G,Sq,Sk) broadcastable bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                      q_chunk: int = 1024, k_chunk: int = 1024,
                      prefix_len: int = 0):
    """Flash-style online-softmax attention, O(chunk^2) memory.

    q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd). causal compares absolute positions
    (q_offset shifts query positions; prefix positions < prefix_len are
    always visible — prefix-LM). kv_len (B,) masks the valid cache length
    for decode. Falls back to a single chunk when sequences are short.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // k_chunk
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0

    qg = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, k_chunk, KV, hd)
    vc = v.reshape(B, nk, k_chunk, KV, hd)
    scale = hd ** -0.5

    def q_block(qi, qb):
        # qb: (B, q_chunk, KV, G, hd)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kb, vb = inputs
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            bias = jnp.zeros((q_chunk, k_chunk), jnp.float32)
            if causal:
                vis = (kpos[None, :] <= qpos[:, None]) | (kpos[None, :] < prefix_len)
                bias = jnp.where(vis, 0.0, -1e30)
            s = s + bias
            if kv_len is not None:
                s = s + jnp.where(kpos[None, :] < kv_len[:, None], 0.0,
                                  -1e30)[:, None, None, None, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # (B, q_chunk, KV, G, hd)

    outs = lax.map(lambda args: q_block(*args),
                   (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def _pad_groups(q, k, v, n_kv, target):
    """Pad the GQA group dim to ``target`` so the model axis divides it.

    Heads are rearranged (KV, G)-major so a contiguous head shard == whole
    KV groups: each model rank then attends its own groups with zero
    communication (pad groups are dead compute, sliced off afterwards)."""
    from repro.models.settings import shard_activation
    B, S, H, hd = q.shape
    G = H // n_kv
    qg = q.reshape(B, S, n_kv, G, hd)
    pad = target - n_kv
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    q = shard_activation(qg.reshape(B, S, target * G, hd), model_dim_axis=2)
    k = shard_activation(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                         model_dim_axis=2)
    v = shard_activation(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
                         model_dim_axis=2)
    return q, k, v, G


def _unpad_groups(out, n_kv, target, G):
    B, S, _, hd = out.shape
    return out.reshape(B, S, target, G, hd)[:, :, :n_kv].reshape(
        B, S, n_kv * G, hd)


def attention_apply(p, x, positions, *, n_kv, head_dim, causal=True,
                    rope_theta=1e4, q_chunk=1024, k_chunk=1024,
                    prefix_len=0, use_rope=True):
    """Self-attention over x: (B,S,D) for train/prefill."""
    from repro.models.settings import attn_group_pad_target
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    target = attn_group_pad_target(n_kv, q.shape[2])
    if target:
        q, k, v, G = _pad_groups(q, k, v, n_kv, target)
    out = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                            k_chunk=k_chunk, prefix_len=prefix_len)
    if target:
        out = _unpad_groups(out, n_kv, target, G)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(p, x, cache_k, cache_v, pos, *, n_kv, head_dim,
                     rope_theta=1e4):
    """Single-token decode. x: (B,1,D); cache_k/v: (B,S_max,KV,hd); pos: (B,).

    Attention over the cache is a single masked softmax (no kv chunk scan):
    with q_len=1 the score tensor is small even at 500k positions, and a flat
    einsum lets GSPMD keep sequence-sharded caches local — the softmax
    max/sum and the PV partial reduce over the sharded sequence dim become
    byte-sized psums instead of cache all-gathers.

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B, S = cache_k.shape[0], cache_k.shape[1]
    KV = cache_k.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, pos[:, None], rope_theta)
    k = rope(k, pos[:, None], rope_theta)
    # scatter new kv at pos
    onehot = jax.nn.one_hot(pos, S, dtype=cache_k.dtype)
    cache_k = cache_k + onehot[:, :, None, None] * (k - jnp.take_along_axis(
        cache_k, pos[:, None, None, None].astype(jnp.int32), axis=1))
    cache_v = cache_v + onehot[:, :, None, None] * (v - jnp.take_along_axis(
        cache_v, pos[:, None, None, None].astype(jnp.int32), axis=1))
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, head_dim)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) * (head_dim ** -0.5)
    kpos = jnp.arange(S)
    scores = scores + jnp.where(kpos[None, :] <= pos[:, None], 0.0,
                                -1e30)[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs,
                     cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, H, head_dim).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


# -------------------------------------------------------- cross-attention

def cross_attention_apply(p, x, memory, *, n_kv, head_dim,
                          q_chunk=1024, k_chunk=1024):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    out = chunked_attention(q, k, v, causal=False, q_chunk=q_chunk,
                            k_chunk=k_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# -------------------------------------------------------------------- mlp

def mlp_init(key, d_model, d_ff, activation):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"wi": _init(k1, (d_model, d_ff), d_model),
                "wg": _init(k2, (d_model, d_ff), d_model),
                "wo": _init(k3, (d_ff, d_model), d_ff)}
    return {"wi": _init(k1, (d_model, d_ff), d_model),
            "wo": _init(k3, (d_ff, d_model), d_ff)}


def mlp_apply(p, x, activation):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if activation == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("bsd,df->bsf", x, p["wg"])
    elif activation == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("bsd,df->bsf", x, p["wg"])
    elif activation == "sq_relu":          # nemotron squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# -------------------------------------------------------------- embedding

def embedding_init(key, vocab, d_model):
    return {"table": _init(key, (vocab, d_model), 1.0) * 0.02}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied readout: (B,S,D) -> (B,S,V) logits."""
    return jnp.einsum("bsd,vd->bsv", x, p["table"])
