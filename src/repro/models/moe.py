"""Mixture-of-Experts layer (grok-1: 8e top-2; granite: 40e top-8).

GShard-style einsum dispatch, sequence-chunked so the (B, chunk, E, C)
dispatch tensors stay small under batch sharding (DESIGN.md §5). Capacity is
per chunk: C = ceil(chunk * k / E * capacity_factor). XLA SPMD partitions
every einsum here (batch on 'data', expert-internal d_ff on 'model').

The dispatch one-hot contraction is exactly a block-sparse SpMM; the
single-host serving path can route it through repro.kernels.spmm with tile
configs from the COGNATE KernelAutotuner (see examples/moe_kernel_serving.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import DTYPE, _init
from repro.models.settings import pin

CAPACITY_FACTOR = 1.25
MOE_CHUNK = 1024


def moe_init(key, arch: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, D, F = arch.n_experts, arch.d_model, arch.d_ff
    s = arch.moe_expert_split
    assert F % s == 0, (F, s)
    # virtual experts: each real expert stored as s contiguous F-slices, so
    # the leading axis (E*s) can be sharded on 'model' (expert parallelism)
    return {
        "router": (jax.random.normal(k1, (D, E)) * D ** -0.5).astype(jnp.float32),
        "wi": _init(k2, (E * s, D, F // s), D),
        "wg": _init(k3, (E * s, D, F // s), D),
        "wo": _init(k4, (E * s, F // s, D), F),
    }


def _capacity(chunk: int, arch: ArchConfig) -> int:
    return max(int(chunk * arch.experts_per_token / arch.n_experts
                   * CAPACITY_FACTOR), arch.experts_per_token)


def moe_chunk_apply(p, arch: ArchConfig, x):
    """x: (B, T, D) one chunk -> (B, T, D)."""
    E, k = arch.n_experts, arch.experts_per_token
    B, T, D = x.shape
    C = _capacity(T, arch)

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(gates, k)                      # (B,T,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert's capacity buffer
    onehot_e = jax.nn.one_hot(topi, E, dtype=jnp.float32)  # (B,T,k,E)
    flat = onehot_e.reshape(B, T * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                  # (B,T*k,E)
    pos = pos.reshape(B, T, k, E)
    within = (pos < C) & (onehot_e > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=DTYPE) \
        * within[..., None].astype(DTYPE)                  # (B,T,k,E,C)
    dispatch = pos_oh.sum(axis=2)                          # (B,T,E,C)
    combine = (pos_oh * topv[..., None, None].astype(DTYPE)).sum(axis=2)

    # virtual experts: route each token's slot to all s slices of its expert
    s = arch.moe_expert_split
    if s > 1:
        dispatch = jnp.repeat(dispatch, s, axis=2)         # (B,T,E*s,C)
        combine = jnp.repeat(combine, s, axis=2)
    dispatch = pin(dispatch, ("batch", None, "model", None))
    combine = pin(combine, ("batch", None, "model", None))
    xin = jnp.einsum("btec,btd->ebcd", dispatch, x)        # (E*s,B,C,D)
    xin = pin(xin, ("model", "batch", None, None))
    h = jnp.einsum("ebcd,edf->ebcf", xin, p["wi"])
    if arch.activation in ("swiglu", "geglu"):
        g = jnp.einsum("ebcd,edf->ebcf", xin, p["wg"])
        act = jax.nn.silu if arch.activation == "swiglu" else jax.nn.gelu
        h = act(h) * g
    else:
        h = jax.nn.gelu(h)
    h = pin(h, ("model", "batch", None, None))
    out = jnp.einsum("ebcf,efd->ebcd", h, p["wo"])
    out = pin(out, ("model", "batch", None, None))
    # combine contracts the (possibly model-sharded) expert axis: with
    # expert parallelism the reduction lands on the (B,T,D) tensor —
    # capacity_factor * k smaller than reducing (E,B,C,D)
    y = jnp.einsum("btec,ebcd->btd", combine, out)
    return pin(y, ("batch", None, None))


def moe_apply(p, arch: ArchConfig, x):
    """x: (B, S, D). Scans MOE_CHUNK-token slices to bound dispatch memory."""
    B, S, D = x.shape
    chunk = min(MOE_CHUNK, S)
    if S % chunk:
        chunk = S  # fallback: single chunk (smoke tests with odd S)
    n = S // chunk
    if n == 1:
        return moe_chunk_apply(p, arch, x)
    xc = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)

    def body(_, xb):
        return None, moe_chunk_apply(p, arch, xb)

    _, out = lax.scan(body, None, xc)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, D)
