"""Decoder-only transformer family (dense, MoE, prefix-LM) and the enc-dec
variant — covers grok-1, granite-moe, deepseek, phi3, nemotron, yi,
paligemma (vision-prefix) and seamless (audio enc-dec).

Layers are scanned (stacked params with a leading L axis) so the lowered HLO
is size-O(1) in depth; remat is applied per layer by the train-step builder.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models.layers import DTYPE
from repro.models.moe import moe_init, moe_apply
from repro.models.settings import maybe_remat, shard_activation, shard_logits


# --------------------------------------------------------------- one layer

def layer_init(key, arch: ArchConfig, cross: bool = False):
    hd = arch.resolved_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "ln1": L.rmsnorm_init(arch.d_model),
        "attn": L.attention_init(ks[0], arch.d_model, arch.n_heads,
                                 arch.n_kv_heads, hd),
        "ln2": L.rmsnorm_init(arch.d_model),
    }
    if arch.n_experts:
        p["moe"] = moe_init(ks[1], arch)
    else:
        p["mlp"] = L.mlp_init(ks[1], arch.d_model, arch.d_ff, arch.activation)
    if cross:
        p["ln_x"] = L.rmsnorm_init(arch.d_model)
        p["xattn"] = L.attention_init(ks[2], arch.d_model, arch.n_heads,
                                      arch.n_kv_heads, hd)
    return p


def layer_apply(p, arch: ArchConfig, x, positions, *, prefix_len=0,
                memory=None, q_chunk=1024, k_chunk=1024):
    hd = arch.resolved_head_dim
    x = shard_activation(x)
    # pinning each projection output anchors the TP partial-sum all-reduce at
    # the bf16 tensor (before the fp32 norm converts) — halves wire bytes
    x = x + shard_activation(L.attention_apply(
        p["attn"], L.rmsnorm(p["ln1"], x, arch.norm_eps), positions,
        n_kv=arch.n_kv_heads, head_dim=hd, causal=True,
        rope_theta=arch.rope_theta, prefix_len=prefix_len,
        q_chunk=q_chunk, k_chunk=k_chunk))
    if memory is not None:
        x = x + shard_activation(L.cross_attention_apply(
            p["xattn"], L.rmsnorm(p["ln_x"], x, arch.norm_eps), memory,
            n_kv=arch.n_kv_heads, head_dim=hd, q_chunk=q_chunk, k_chunk=k_chunk))
    h = L.rmsnorm(p["ln2"], x, arch.norm_eps)
    if arch.n_experts:
        x = x + shard_activation(moe_apply(p["moe"], arch, h))
    else:
        x = x + shard_activation(L.mlp_apply(p["mlp"], h, arch.activation))
    return shard_activation(x)


def layer_decode(p, arch: ArchConfig, x, cache, pos, *, memory=None):
    """x: (B,1,D); cache: {"k","v"} (B,Smax,KV,hd). Returns (x, cache)."""
    hd = arch.resolved_head_dim
    h = L.rmsnorm(p["ln1"], x, arch.norm_eps)
    attn_out, ck, cv = L.attention_decode(
        p["attn"], h, cache["k"], cache["v"], pos, n_kv=arch.n_kv_heads,
        head_dim=hd, rope_theta=arch.rope_theta)
    x = x + attn_out
    if memory is not None:
        x = x + L.cross_attention_apply(
            p["xattn"], L.rmsnorm(p["ln_x"], x, arch.norm_eps), memory,
            n_kv=arch.n_kv_heads, head_dim=hd, q_chunk=1)
    h = L.rmsnorm(p["ln2"], x, arch.norm_eps)
    if arch.n_experts:
        x = x + moe_apply(p["moe"], arch, h)
    else:
        x = x + L.mlp_apply(p["mlp"], h, arch.activation)
    return x, {"k": ck, "v": cv}


# ----------------------------------------------------------- decoder stack

def _stacked_init(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


class DecoderLM:
    """Dense / MoE / prefix-LM decoder. Prefix embeddings (vision patches,
    precomputed frames) are injected before the token embeddings and made
    bidirectionally visible (prefix-LM masking), per the assignment's stub
    rule for [vlm] frontends."""

    def __init__(self, arch: ArchConfig):
        self.arch = arch

    # ---- params
    def init(self, key):
        arch = self.arch
        k1, k2, k3 = jax.random.split(key, 3)
        params = {
            "embed": L.embedding_init(k1, arch.vocab, arch.d_model),
            "layers": _stacked_init(k2, arch.n_layers,
                                    lambda k: layer_init(k, arch)),
            "final_norm": L.rmsnorm_init(arch.d_model),
        }
        if arch.n_prefix_tokens:
            params["prefix_proj"] = {
                "w": (jax.random.normal(k3, (arch.prefix_dim or arch.d_model,
                                             arch.d_model)) * 0.02).astype(DTYPE)}
        return params

    # ---- shared trunk
    def _hidden(self, params, tokens, prefix_embed=None, q_chunk=1024,
                k_chunk=1024):
        arch = self.arch
        x = shard_activation(L.embed(params["embed"], tokens))
        prefix_len = 0
        if prefix_embed is not None:
            pe = jnp.einsum("bpe,ed->bpd", prefix_embed.astype(DTYPE),
                            params["prefix_proj"]["w"])
            x = jnp.concatenate([pe, x], axis=1)
            prefix_len = pe.shape[1]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, lp):
            return layer_apply(lp, arch, x, positions, prefix_len=prefix_len,
                               q_chunk=q_chunk, k_chunk=k_chunk), None

        x, _ = lax.scan(maybe_remat(body), x, params["layers"])
        return L.rmsnorm(params["final_norm"], x, arch.norm_eps), prefix_len

    # ---- training
    def train_loss(self, params, batch):
        arch = self.arch
        x, prefix_len = self._hidden(params, batch["tokens"],
                                     batch.get("prefix"))
        x = x[:, prefix_len:]
        logits = shard_logits(L.unembed(params["embed"], x))
        targets = batch["targets"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        mask = (targets >= 0).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
        return loss, {"loss": loss}

    # ---- prefill: full forward producing last-position logits + KV cache
    def prefill_step(self, params, batch):
        x, prefix_len = self._hidden(params, batch["tokens"],
                                     batch.get("prefix"))
        logits = L.unembed(params["embed"], x[:, -1:])
        return logits[:, 0]

    # ---- cached decode
    def init_cache(self, batch: int, max_len: int):
        arch = self.arch
        hd = arch.resolved_head_dim
        shape = (arch.n_layers, batch, max_len, arch.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, DTYPE), "v": jnp.zeros(shape, DTYPE),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def serve_step(self, params, cache, tokens):
        """tokens: (B,) -> (logits (B,V), new cache). One decode step."""
        arch = self.arch
        x = L.embed(params["embed"], tokens[:, None])
        pos = cache["pos"]

        def body(x, scanned):
            lp, ck, cv = scanned
            x, new = layer_decode(lp, arch, x, {"k": ck, "v": cv}, pos)
            return x, (new["k"], new["v"])

        x, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
        x = L.rmsnorm(params["final_norm"], x, arch.norm_eps)
        logits = L.unembed(params["embed"], x)[:, 0]
        return logits, {"k": nk, "v": nv, "pos": pos + 1}

    # ---- dry-run input specs
    def input_specs(self, shape: ShapeConfig):
        arch = self.arch
        B, S = shape.global_batch, shape.seq_len
        P = arch.n_prefix_tokens
        tok = jax.ShapeDtypeStruct((B, max(S - P, 1)), jnp.int32)
        specs = {"tokens": tok}
        if P:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (B, P, arch.prefix_dim or arch.d_model), DTYPE)
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, max(S - P, 1)), jnp.int32)
        return specs


# ----------------------------------------------------------------- enc-dec

class EncDecLM:
    """Encoder-decoder (seamless-m4t): stub audio frame embeddings in, text
    tokens out. Encoder is bidirectional; decoder adds cross-attention."""

    SRC_FRACTION = 4   # source frames = seq_len // 4 (documented in DESIGN.md)

    def __init__(self, arch: ArchConfig):
        self.arch = arch

    def init(self, key):
        arch = self.arch
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": L.embedding_init(k1, arch.vocab, arch.d_model),
            "enc_layers": _stacked_init(k2, arch.n_enc_layers,
                                        lambda k: layer_init(k, arch)),
            "enc_norm": L.rmsnorm_init(arch.d_model),
            "dec_layers": _stacked_init(
                k3, arch.n_layers, lambda k: layer_init(k, arch, cross=True)),
            "final_norm": L.rmsnorm_init(arch.d_model),
        }

    def _encode(self, params, frames, q_chunk=1024, k_chunk=1024):
        arch = self.arch
        x = shard_activation(frames.astype(DTYPE))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, lp):
            # bidirectional: prefix_len = S makes everything visible
            return layer_apply(lp, arch, x, positions, prefix_len=S,
                               q_chunk=q_chunk, k_chunk=k_chunk), None

        x, _ = lax.scan(maybe_remat(body), x, params["enc_layers"])
        return L.rmsnorm(params["enc_norm"], x, arch.norm_eps)

    def _decode_train(self, params, tokens, memory, q_chunk=1024):
        arch = self.arch
        x = shard_activation(L.embed(params["embed"], tokens))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, lp):
            return layer_apply(lp, arch, x, positions, memory=memory,
                               q_chunk=q_chunk), None

        x, _ = lax.scan(maybe_remat(body), x, params["dec_layers"])
        return L.rmsnorm(params["final_norm"], x, arch.norm_eps)

    def train_loss(self, params, batch):
        memory = self._encode(params, batch["src_frames"])
        x = self._decode_train(params, batch["tokens"], memory)
        logits = shard_logits(L.unembed(params["embed"], x))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                                   axis=-1)[..., 0]
        mask = (batch["targets"] >= 0).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
        return loss, {"loss": loss}

    def prefill_step(self, params, batch):
        memory = self._encode(params, batch["src_frames"])
        x = self._decode_train(params, batch["tokens"], memory)
        return L.unembed(params["embed"], x[:, -1:])[:, 0]

    def init_cache(self, batch: int, max_len: int):
        arch = self.arch
        hd = arch.resolved_head_dim
        src = max(max_len // self.SRC_FRACTION, 1)
        shape = (arch.n_layers, batch, max_len, arch.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, DTYPE), "v": jnp.zeros(shape, DTYPE),
                "memory": jnp.zeros((batch, src, arch.d_model), DTYPE),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def serve_step(self, params, cache, tokens):
        arch = self.arch
        x = L.embed(params["embed"], tokens[:, None])
        pos = cache["pos"]
        memory = cache["memory"]

        def body(x, scanned):
            lp, ck, cv = scanned
            x, new = layer_decode(lp, arch, x, {"k": ck, "v": cv}, pos,
                                  memory=memory)
            return x, (new["k"], new["v"])

        x, (nk, nv) = lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"]))
        x = L.rmsnorm(params["final_norm"], x, arch.norm_eps)
        logits = L.unembed(params["embed"], x)[:, 0]
        return logits, {"k": nk, "v": nv, "memory": memory, "pos": pos + 1}

    def input_specs(self, shape: ShapeConfig):
        arch = self.arch
        B, S = shape.global_batch, shape.seq_len
        src = max(S // self.SRC_FRACTION, 1)
        specs = {"src_frames": jax.ShapeDtypeStruct((B, src, arch.d_model), DTYPE),
                 "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
