"""Linear-recurrence substrate: chunked gated linear attention (GLA/SSD form)
shared by the mLSTM (xLSTM) and Mamba2 (zamba2) blocks, plus the sLSTM
sequential cell.

Recurrence (per head):   S_t = a_t * S_{t-1} + k_t v_t^T,   o_t = S_t^T q_t
with scalar per-head decay a_t = exp(log_a_t) in (0,1]. The chunkwise-parallel
form (Mamba2's SSD / GLA) computes within-chunk terms as masked attention and
carries the (dk x dv) state across chunks with a lax.scan — O(S*L) work,
TPU-friendly einsums, exact (no approximation).

Decode is the one-step recurrence on a carried state — O(1) per token, which
is what makes the long_500k shape feasible for the ssm/hybrid families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def gla_chunked(q, k, v, log_a, *, chunk: int = 256, state0=None,
                normalize: bool = True):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_a: (B,S,H) (<= 0).

    Returns (o: (B,S,H,dv), final_state: (B,H,dk,dv), final_norm: (B,H,dk)).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    qc = q.reshape(B, n_chunks, chunk, H, dk)
    kc = k.reshape(B, n_chunks, chunk, H, dk)
    vc = v.reshape(B, n_chunks, chunk, H, dv)
    la = log_a.reshape(B, n_chunks, chunk, H)

    if state0 is None:
        state0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    norm0 = jnp.zeros((B, H, dk), jnp.float32)

    def chunk_step(carry, inputs):
        S_c, n_c = carry                       # (B,H,dk,dv), (B,H,dk)
        qb, kb, vb, lab = inputs               # (B,chunk,H,*)
        qb32 = qb.astype(jnp.float32)
        kb32 = kb.astype(jnp.float32)
        vb32 = vb.astype(jnp.float32)
        A = jnp.cumsum(lab.astype(jnp.float32), axis=1)       # (B,chunk,H)
        a_end = A[:, -1]                                       # (B,H)
        # cross-chunk contribution: o_t += exp(A_t) * q_t^T S_in
        q_dec = qb32 * jnp.exp(A)[..., None]
        o_cross = jnp.einsum("bthk,bhkv->bthv", q_dec, S_c)
        n_cross = jnp.einsum("bthk,bhk->bth", q_dec, n_c)
        # within-chunk: masked decay attention exp(A_t - A_j) (j <= t)
        gap = A[:, :, None, :] - A[:, None, :, :]              # (B,t,j,H)
        t_idx = jnp.arange(chunk)
        causal = t_idx[:, None] >= t_idx[None, :]
        decay = jnp.where(causal[None, :, :, None], jnp.exp(gap), 0.0)
        scores = jnp.einsum("bthk,bjhk->btjh", qb32, kb32) * decay
        o_in = jnp.einsum("btjh,bjhv->bthv", scores, vb32)
        n_in = scores.sum(axis=2)                              # (B,t,H)
        o = o_cross + o_in
        n = n_cross + n_in
        # state update: S_out = exp(a_end) S_in + sum_j exp(a_end - A_j) k_j v_j^T
        k_dec = kb32 * jnp.exp(a_end[:, None] - A)[..., None]
        S_new = S_c * jnp.exp(a_end)[..., None, None] + \
            jnp.einsum("bjhk,bjhv->bhkv", k_dec, vb32)
        n_new = n_c * jnp.exp(a_end)[..., None] + jnp.einsum("bjhk->bhk", k_dec)
        return (S_new, n_new), (o, n)

    (S_f, n_f), (o_all, n_all) = lax.scan(
        chunk_step, (state0, norm0),
        (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
         jnp.moveaxis(vc, 1, 0), jnp.moveaxis(la, 1, 0)))
    o = jnp.moveaxis(o_all, 0, 1).reshape(B, S, H, dv)
    n = jnp.moveaxis(n_all, 0, 1).reshape(B, S, H)
    if normalize:
        o = o / jnp.maximum(jnp.abs(n)[..., None], 1.0)
    return o.astype(q.dtype), S_f, n_f


def gla_step(state, norm, q, k, v, log_a, normalize: bool = True):
    """One decode step. q,k: (B,H,dk); v: (B,H,dv); log_a: (B,H).

    Returns (o: (B,H,dv), new_state, new_norm)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = state * a + jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    norm = norm * a[..., 0] + k.astype(jnp.float32)
    o = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    if normalize:
        nrm = jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), norm)
        o = o / jnp.maximum(jnp.abs(nrm)[..., None], 1.0)
    return o.astype(q.dtype), state, norm


# ------------------------------------------------------------------ sLSTM

def slstm_step(carry, g, r_weight):
    """One sLSTM step with exponential gating + recurrent mixing.

    carry: (h, c, n, m) each (B,H,dh) fp32; g: (B,H,4dh) input
    pre-activations; r_weight: (H, dh, 4dh) head-local recurrent kernel."""
    h, c, n, m = carry
    g = g.astype(jnp.float32) + jnp.einsum("bhd,hdf->bhf", h, r_weight)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    log_f = -jax.nn.softplus(-gf)            # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * jnp.tanh(gz)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_scan(x_gates, r_weight, carry0):
    """Sequential sLSTM (xLSTM eq. 14-19) over (B,S,H,4dh) pre-activations.

    The hidden-to-gate recurrence (r_weight) is what makes sLSTM inherently
    sequential — no chunked parallel form exists (xLSTM §2.1)."""
    r32 = r_weight.astype(jnp.float32)

    def step(carry, g):
        return slstm_step(carry, g, r32)

    carry, hs = lax.scan(step, carry0, jnp.moveaxis(x_gates, 1, 0))
    return jnp.moveaxis(hs, 0, 1), carry
