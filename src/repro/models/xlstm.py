"""xLSTM-350M: mLSTM blocks (chunked matrix-memory linear recurrence) with an
sLSTM block every ``slstm_every`` positions (xLSTM[7:1] ratio).

mLSTM is attention-free and O(S) — it runs the long_500k cell. Decode carries
per-head (dk x dv) matrix states; there is no KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models.layers import DTYPE, _init
from repro.models.ssm import gla_chunked, gla_step, slstm_scan, slstm_step
from repro.models.settings import maybe_remat, shard_activation, shard_logits


# ----------------------------------------------------------------- mLSTM

def mlstm_init(key, arch: ArchConfig):
    D = arch.d_model
    H = arch.n_heads
    dh = arch.resolved_head_dim
    up = 2 * D                       # xLSTM up-projection factor 2
    ks = jax.random.split(key, 8)
    return {
        "ln": L.rmsnorm_init(D),
        "w_up": _init(ks[0], (D, up), D),
        "w_gate": _init(ks[1], (D, up), D),
        "wq": _init(ks[2], (up, H, dh), up),
        "wk": _init(ks[3], (up, H, dh), up),
        "wv": _init(ks[4], (up, H, dh), up),
        "w_if": (jax.random.normal(ks[5], (D, 2 * H)) * 0.02).astype(jnp.float32),
        "b_if": jnp.zeros((2 * H,), jnp.float32),
        "out_norm": L.rmsnorm_init(H * dh),
        "w_down": _init(ks[6], (H * dh, D), H * dh),
    }


def _mlstm_qkv(p, arch, xn):
    u = jnp.einsum("bsd,du->bsu", xn, p["w_up"])
    q = jnp.einsum("bsu,uhk->bshk", u, p["wq"])
    k = jnp.einsum("bsu,uhk->bshk", u, p["wk"]) * (arch.resolved_head_dim ** -0.5)
    v = jnp.einsum("bsu,uhk->bshk", u, p["wv"])
    gif = jnp.einsum("bsd,dh->bsh", xn.astype(jnp.float32), p["w_if"]) + p["b_if"]
    H = arch.n_heads
    gi, gf = gif[..., :H], gif[..., H:]
    log_f = -jax.nn.softplus(-gf)            # log sigmoid forget gate
    # input gate folded into k (exponential gating, stabilized by sigmoid)
    k = k * jax.nn.sigmoid(gi)[..., None].astype(k.dtype)
    gate = jax.nn.silu(jnp.einsum("bsd,du->bsu", xn, p["w_gate"]))
    return q, k, v, log_f, gate, u


def mlstm_apply(p, arch: ArchConfig, x, chunk=256):
    x = shard_activation(x)
    xn = L.rmsnorm(p["ln"], x, arch.norm_eps)
    q, k, v, log_f, gate, _ = _mlstm_qkv(p, arch, xn)
    o, _, _ = gla_chunked(q, k, v, log_f, chunk=min(chunk, x.shape[1]))
    B, S, H, dh = o.shape
    o = L.rmsnorm(p["out_norm"], o.reshape(B, S, H * dh), arch.norm_eps)
    o = o * gate[..., :H * dh]
    return x + jnp.einsum("bsu,ud->bsd", o, p["w_down"])


def mlstm_decode(p, arch: ArchConfig, x, state, norm):
    """x: (B,1,D); state: (B,H,dk,dv); norm: (B,H,dk)."""
    xn = L.rmsnorm(p["ln"], x, arch.norm_eps)
    q, k, v, log_f, gate, _ = _mlstm_qkv(p, arch, xn)
    o, state, norm = gla_step(state, norm, q[:, 0], k[:, 0], v[:, 0],
                              log_f[:, 0])
    B, H, dh = o.shape
    o = L.rmsnorm(p["out_norm"], o.reshape(B, 1, H * dh), arch.norm_eps)
    o = o * gate[:, :1, :H * dh]
    return x + jnp.einsum("bsu,ud->bsd", o, p["w_down"]), state, norm


# ----------------------------------------------------------------- sLSTM

def slstm_init(key, arch: ArchConfig):
    D = arch.d_model
    H = arch.n_heads
    dh = arch.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "ln": L.rmsnorm_init(D),
        "w_gates": _init(ks[0], (D, H, 4 * dh), D),
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh)) * dh ** -0.5
              ).astype(jnp.float32),
        "w_down": _init(ks[2], (H * dh, D), H * dh),
        "out_norm": L.rmsnorm_init(H * dh),
    }


def _slstm_carry0(B, H, dh):
    z = jnp.zeros((B, H, dh), jnp.float32)
    return (z, z, z, z - 10.0)   # m0 low so early exp() doesn't saturate


def slstm_apply(p, arch: ArchConfig, x):
    xn = L.rmsnorm(p["ln"], x, arch.norm_eps)
    gates = jnp.einsum("bsd,dhf->bshf", xn, p["w_gates"])
    B, S, H, _ = gates.shape
    dh = arch.resolved_head_dim
    h, _ = slstm_scan(gates, p["r"], _slstm_carry0(B, H, dh))
    h = L.rmsnorm(p["out_norm"], h.reshape(B, S, H * dh).astype(DTYPE),
                  arch.norm_eps)
    return x + jnp.einsum("bsu,ud->bsd", h, p["w_down"])


def slstm_decode(p, arch: ArchConfig, x, carry):
    xn = L.rmsnorm(p["ln"], x, arch.norm_eps)
    gates = jnp.einsum("bsd,dhf->bshf", xn, p["w_gates"])[:, 0]
    carry, h = slstm_step(carry, gates, p["r"].astype(jnp.float32))
    B, H, dh = h.shape
    h = L.rmsnorm(p["out_norm"], h.reshape(B, 1, H * dh).astype(DTYPE),
                  arch.norm_eps)
    return x + jnp.einsum("bsu,ud->bsd", h, p["w_down"]), carry


# ------------------------------------------------------------------ model

class XLSTM:
    def __init__(self, arch: ArchConfig):
        self.arch = arch
        k = arch.slstm_every or 0
        self.slstm_idx = [i for i in range(arch.n_layers)
                          if k and (i % k == k - 1)]
        self.mlstm_idx = [i for i in range(arch.n_layers)
                          if i not in self.slstm_idx]

    def init(self, key):
        arch = self.arch
        k1, k2, k3 = jax.random.split(key, 3)
        keys_m = jax.random.split(k2, max(len(self.mlstm_idx), 1))
        params = {
            "embed": L.embedding_init(k1, arch.vocab, arch.d_model),
            "mlstm": jax.vmap(lambda k: mlstm_init(k, arch))(keys_m),
            "final_norm": L.rmsnorm_init(arch.d_model),
        }
        if self.slstm_idx:
            keys_s = jax.random.split(k3, len(self.slstm_idx))
            params["slstm"] = jax.vmap(lambda k: slstm_init(k, arch))(keys_s)
        return params

    def _hidden(self, params, tokens):
        arch = self.arch
        x = shard_activation(L.embed(params["embed"], tokens))

        # scan contiguous mLSTM groups, interleave sLSTM blocks (unrolled —
        # there are only n_layers/slstm_every of them, weights differ)
        def m_body(x, lp):
            return mlstm_apply(lp, arch, x), None

        m_body = maybe_remat(m_body)

        if not self.slstm_idx:
            x, _ = lax.scan(m_body, x, params["mlstm"])
        else:
            per_group = arch.slstm_every - 1
            m_off = 0
            for si in range(len(self.slstm_idx)):
                group = jax.tree_util.tree_map(
                    lambda a, o=m_off: a[o:o + per_group], params["mlstm"])
                x, _ = lax.scan(m_body, x, group)
                m_off += per_group
                sp = jax.tree_util.tree_map(lambda a, i=si: a[i],
                                            params["slstm"])
                x = slstm_apply(sp, arch, x)
            rem = len(self.mlstm_idx) - m_off
            if rem:
                group = jax.tree_util.tree_map(lambda a: a[m_off:], params["mlstm"])
                x, _ = lax.scan(m_body, x, group)
        return L.rmsnorm(params["final_norm"], x, arch.norm_eps)

    def train_loss(self, params, batch):
        x = self._hidden(params, batch["tokens"])
        logits = shard_logits(L.unembed(params["embed"], x))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                                   axis=-1)[..., 0]
        mask = (batch["targets"] >= 0).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
        return loss, {"loss": loss}

    def prefill_step(self, params, batch):
        x = self._hidden(params, batch["tokens"])
        return L.unembed(params["embed"], x[:, -1:])[:, 0]

    def init_cache(self, batch: int, max_len: int):
        arch = self.arch
        H, dh = arch.n_heads, arch.resolved_head_dim
        nm, ns = len(self.mlstm_idx), len(self.slstm_idx)
        cache = {
            "m_state": jnp.zeros((nm, batch, H, dh, dh), jnp.float32),
            "m_norm": jnp.zeros((nm, batch, H, dh), jnp.float32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
        if ns:
            z = jnp.zeros((ns, batch, H, dh), jnp.float32)
            cache["s_carry"] = (z, z, z, z - 10.0)
        return cache

    def serve_step(self, params, cache, tokens):
        arch = self.arch
        x = L.embed(params["embed"], tokens[:, None])
        m_states, m_norms = [], []
        s_carries = []
        mi = si = 0
        for layer in range(arch.n_layers):
            if layer in self.slstm_idx:
                sp = jax.tree_util.tree_map(lambda a, i=si: a[i], params["slstm"])
                carry = jax.tree_util.tree_map(lambda a, i=si: a[i],
                                               cache["s_carry"])
                x, carry = slstm_decode(sp, arch, x, carry)
                s_carries.append(carry)
                si += 1
            else:
                lp = jax.tree_util.tree_map(lambda a, i=mi: a[i], params["mlstm"])
                x, st, nr = mlstm_decode(lp, arch, x,
                                         cache["m_state"][mi], cache["m_norm"][mi])
                m_states.append(st)
                m_norms.append(nr)
                mi += 1
        x = L.rmsnorm(params["final_norm"], x, arch.norm_eps)
        logits = L.unembed(params["embed"], x)[:, 0]
        new = {"m_state": jnp.stack(m_states), "m_norm": jnp.stack(m_norms),
               "pos": cache["pos"] + 1}
        if s_carries:
            new["s_carry"] = tuple(jnp.stack([c[i] for c in s_carries])
                                   for i in range(4))
        return logits, new

    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
