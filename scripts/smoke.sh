#!/usr/bin/env bash
# Smoke check: the tier-1 suite plus the serving stack, so the
# pattern -> tuned-kernel fast path (format conversion, autotune cache,
# Pallas SpMM) and the serving engine (batched scoring, multi-backend
# dispatch, plan arena, cache persistence) can't silently rot — plus a docs
# check so README/docs never reference files, modules, or benchmark names
# that no longer exist. Run from the repo root:
#   bash scripts/smoke.sh
#
# SMOKE_QUICK=1 runs the reduced CI path: docs check, example, and the quick
# serving/routing/faults/observability/shard/admission benchmarks — skipping
# tier-1 (CI
# runs it as its own step), the slow stress tests, and the bsr_preproc bench.
# The benchmark run exports XLA_FLAGS=--xla_force_host_platform_device_count=8
# (scoped to that invocation: tier-1 exercises the single-device mesh paths)
# so the sharded-serving scenarios place replicas over 8 real XLA devices.
# SMOKE_FAULTS=1 additionally re-runs the degraded-mode fault benchmark
# standalone (full length) after the gates.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
QUICK="${SMOKE_QUICK:-0}"

# On any failing step, surface the engines' debug artifacts (full stats()
# snapshots, tail-retained error-ring traces, structured event logs) that
# the benchmarks drop under benchmarks/artifacts/*_debug.json — so a CI
# log carries the evidence, not just the tripped assertion.
dump_debug_artifacts() {
  echo "== FAILURE: dumping engine debug artifacts =="
  for f in benchmarks/artifacts/*_debug.json; do
    [ -e "$f" ] || continue
    echo "--- $f"
    cat "$f"
  done
}
trap dump_debug_artifacts ERR

echo "== docs reference check =="
python - <<'EOF'
"""README/docs must reference real files, importable modules, and
registered benchmark names."""
import re
import sys
from pathlib import Path

failures = []
doc_files = [Path("README.md"), *sorted(Path("docs").glob("*.md"))]
top_dirs = ("src/", "benchmarks/", "examples/", "tests/", "docs/",
            "scripts/")

# 1. every repo-path-looking token in the docs exists on disk
path_re = re.compile(r"[A-Za-z0-9_./-]+\.(?:py|md|sh|ini|txt)\b")
for doc in doc_files:
    for tok in path_re.findall(doc.read_text()):
        if tok.startswith(top_dirs) or ("/" not in tok and tok.endswith(".md")):
            if not Path(tok).exists():
                failures.append(f"{doc}: references missing file {tok}")

# 2. documented modules import
for mod in ("repro.serving", "repro.serving.backends", "repro.serving.engine",
            "repro.serving.persist", "repro.serving.arena",
            "repro.serving.router", "repro.serving.telemetry",
            "repro.serving.health", "repro.serving.faults",
            "repro.serving.trace", "repro.serving.export",
            "repro.serving.shard", "repro.serving.admission",
            "repro.launch.mesh",
            "repro.parallel.sharding",
            "repro.core.autotune", "repro.kernels.ops", "repro.kernels.ref"):
    try:
        __import__(mod)
    except Exception as e:
        failures.append(f"documented module {mod} failed to import: {e}")

# 3. documented entry points resolve
try:
    from repro.serving import (BackendRegistry, CostModelRouter, HashRing,
                               KernelBackend, KernelRequest, LoadAwareRouter,
                               ShardedEngine, SparseKernelEngine, StaticRouter,
                               default_registry, load_grouped, save_backends)
    reg = default_registry()
    for plat in ("tpu_interpret", "tpu_pallas", "cpu_ref"):
        reg.get(plat, "spmm")
except Exception as e:
    failures.append(f"documented serving API broken: {e}")

# 4. benchmark names named in the docs are registered in benchmarks/run.py
run_py = Path("benchmarks/run.py").read_text()
for name in ("serving", "routing", "faults", "observability", "shard",
             "admission", "bsr_preproc", "fig4", "kernel"):
    if f'("{name}"' not in run_py:
        failures.append(f"documented benchmark {name!r} not in benchmarks/run.py")

if failures:
    print("\n".join(failures))
    sys.exit(1)
print(f"docs OK: {len(doc_files)} files checked")
EOF

if [ "$QUICK" != "1" ]; then
  echo "== tier-1 tests =="
  python -m pytest -x -q

  echo "== slow stress tests (persistence/arena/threading) =="
  python -m pytest -q -m slow
fi

echo "== MoE kernel serving example (engine-driven) =="
python examples/moe_kernel_serving.py

if [ "$QUICK" != "1" ]; then
  echo "== bsr_preproc benchmark =="
  python -m benchmarks.run bsr_preproc
fi

echo "== serving + routing + faults + observability + shard + admission benchmarks (quick) -> BENCH_10.json =="
# The 8-device flag is scoped to this invocation: the sharded scenarios
# need a real multi-device host platform, while tier-1 above runs the
# stock single-device mesh.  It must be in the environment before jax
# initializes, which is why it rides the command, not a jax call.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
REPRO_BENCH_QUICK=1 python -m benchmarks.run serving routing faults \
  observability shard admission --json BENCH_10.json

echo "== device_build overlap gate =="
python - <<'EOF'
"""The async pipeline must not regress below the synchronous path: the
device_build scenario's overlapped req/s is gated against the per-step
drain() baseline.  On a saturated single-CPU container the expected
ratio is ~1.0 (compute has no spare core to overlap into), so a small
noise tolerance applies — the gate catches the async path becoming
*materially* slower than draining every step, which is the regression
mode this guards against."""
import json

doc = json.load(open("BENCH_10.json"))
by = {r["name"]: r for r in doc["rows"]}
ov = by["serving/device_build/overlapped_requests_per_s"]["metrics"]["req_per_s"]
sy = by["serving/device_build/synchronous_requests_per_s"]["metrics"]["req_per_s"]
host = by["serving/device_build/overlapped_requests_per_s"]["metrics"]["host_builds"]
print(f"overlapped={ov:.1f} req/s synchronous={sy:.1f} req/s "
      f"({ov / sy:.2f}x), host_builds={host:.0f}")
assert host == 0, "warm device-resident mix did host-numpy scatters"
assert ov >= 0.95 * sy, (
    f"overlapped execute ({ov:.1f} req/s) regressed below the "
    f"synchronous path ({sy:.1f} req/s)")
EOF

echo "== warm fast-path gate =="
python - <<'EOF'
"""The fused warm lane must actually beat the naive PR-1 loop on hot
traffic: engine req/s >= 1.2x the sequential get+reuse-build baseline
(measured interleaved A/B, best-of per mode — the margin is headroom,
not noise allowance; the lane prototypes at ~3x on this container), and
the async pipeline must be live inside segments: overlap_ratio >= 0.6
(drain only at segment ends -> all but each segment's first step build
over an in-flight generation).  The benchmark itself asserts every
timed step took the lane and the fused build path."""
import json

doc = json.load(open("BENCH_10.json"))
by = {r["name"]: r for r in doc["rows"]}
e = by["serving/warm_lane/engine_requests_per_s"]["metrics"]
b = by["serving/warm_lane/pr1_loop_requests_per_s"]["metrics"]
print(f"warm lane={e['req_per_s']:.0f} req/s pr1_loop={b['req_per_s']:.0f} "
      f"req/s ({b['engine_speedup']:.2f}x), "
      f"overlap_ratio={e['overlap_ratio']:.2f}, "
      f"warm_steps={e['warm_steps']:.0f} fused={e['fused_builds']:.0f}")
assert b["engine_speedup"] >= 1.2, (
    f"warm lane {b['engine_speedup']:.2f}x over the PR-1 loop "
    f"(gate: >=1.2x)")
assert e["overlap_ratio"] >= 0.6, (
    f"warm-lane overlap_ratio {e['overlap_ratio']:.2f} (gate: >=0.6) — "
    f"the lane is serializing instead of dispatching async")
EOF

echo "== degraded-mode fault gate =="
python - <<'EOF'
"""Kill-one-backend scenario: the deterministic degradation contract
(zero lost requests, bit-exact failovers, breaker opens -> half-open
probe -> recovery) is asserted inside benchmarks/serving_faults.py
itself; this gate checks the accounting landed in the artifact and the
one machine-dependent number — p99 on the surviving mix must stay
within 3x the no-fault baseline (the retry lane roughly doubles the
kill step's work; 3x leaves noise headroom without letting a
pathological retry path through)."""
import json

doc = json.load(open("BENCH_10.json"))
by = {r["name"]: r for r in doc["rows"]}
m = by["faults/degraded/requests_per_s"]["metrics"]
print(f"degraded p99={m['p99_ms']:.2f}ms "
      f"({m['p99_inflation_x']:.2f}x baseline), "
      f"lost={m['lost_requests']:.0f} failovers={m['failovers']:.0f} "
      f"opens={m['breaker_opens']:.0f} recovered={m['recovered']:.0f}")
assert m["lost_requests"] == 0, "requests lost during backend failure"
assert m["recovered"] == 1, "breaker never recovered via half-open probe"
assert m["failovers"] == m["execute_failures"], "unaccounted failures"
assert m["p99_inflation_x"] <= 3.0, (
    f"degraded p99 inflated {m['p99_inflation_x']:.2f}x over the "
    f"no-fault baseline (gate: 3x)")
g = by["faults/nan_guard/guarded_failovers"]["metrics"]
assert g["output_guard_failures"] == g["failovers"] > 0
EOF

echo "== observability gate =="
python - <<'EOF'
"""Tracing must stay near-free and incidents must never be sampled away:
sampled tracing (rate 0.1) may cost at most 5% req/s vs tracing-off
(interleaved best-of protocol, so the margin is real headroom, not noise
allowance), and the fault scenario must show every degraded/failed-over
request tail-retained in the error ring with a complete span tree —
asserted in-process by benchmarks/serving_observability.py, checked here
to have landed in the artifact.  Also re-validates the exported
Prometheus scrape parses and the Chrome trace loads."""
import json

from repro.serving import parse_prometheus_text

doc = json.load(open("BENCH_10.json"))
by = {r["name"]: r for r in doc["rows"]}
m = by["observability/tracing_sampled/requests_per_s"]["metrics"]
print(f"tracing overhead={m['overhead_pct']:.2f}% at "
      f"rate={m['sample_rate']} ({m['sampled_steps']:.0f}/"
      f"{m['steps']:.0f} steps materialized)")
assert m["overhead_pct"] <= 5.0, (
    f"sampled tracing cost {m['overhead_pct']:.2f}% req/s "
    f"(gate: 5%)")
e = by["observability/error_ring/complete"]["metrics"]
assert e["error_ring_complete"] == 1, "error ring lost a degraded trace"
assert e["error_traces"] > 0, "fault scenario produced no error traces"
samples = parse_prometheus_text(
    open("benchmarks/artifacts/obs_prometheus.txt").read())
trace = json.load(open("benchmarks/artifacts/obs_chrome_trace.json"))
assert samples and trace["traceEvents"]
print(f"error_ring_complete=1 ({e['error_traces']:.0f} traces); "
      f"prometheus scrape {len(samples)} samples; chrome trace "
      f"{len(trace['traceEvents'])} events")
EOF

echo "== sharded-serving gate =="
python - <<'EOF'
"""The sharded fleet must earn its replicas and never trade correctness
for them: (1) the capacity scenario's 4-replica fleet serves the
cache-overflowing working set >= 2.5x the single replica's req/s
(aggregate cache capacity is the mechanism — the single replica
LRU-thrashes, the fleet goes warm); (2) the live rebalance lost zero
requests while the fleet grew and shrank under load; (3) the sharded
outputs matched the unsharded reference bit for bit and the rebalance
migrated cache rows warm (migrated > 0, featurize delta 0 when
synchronized); (4) the run really placed replicas over the 8-device
host mesh the XLA flag stands up."""
import json

doc = json.load(open("BENCH_10.json"))
by = {r["name"]: r for r in doc["rows"]}
cold = by["shard/cold/n1_requests_per_s"]["metrics"]
print(f"shard capacity speedup={cold['speedup']:.2f}x "
      f"(n4={by['shard/cold/n4_requests_per_s']['metrics']['req_per_s']:.0f} "
      f"req/s, n1={cold['req_per_s']:.0f} req/s)")
assert cold["speedup"] >= 2.5, (
    f"4-replica fleet {cold['speedup']:.2f}x over one replica on the "
    f"capacity mix (gate: >=2.5x)")
ul = by["shard/rebalance/under_load_lost_requests"]["metrics"]
print(f"rebalance under load: lost={ul['lost_requests']:.0f} "
      f"served={ul['served']:.0f} rebalances={ul['rebalances']:.0f} "
      f"migrated={ul['migrated_entries']:.0f}")
assert ul["lost_requests"] == 0, "rebalance under load lost requests"
assert ul["rebalances"] == 2, "grow+shrink did not both happen"
sync = by["shard/rebalance/synchronized"]["metrics"]
assert sync["outputs_match"] == 1, \
    "sharded outputs diverged from the unsharded reference"
assert sync["migrated_entries"] > 0, "rebalance migrated no cache rows"
assert sync["featurize_delta"] == 0, (
    f"synchronized rebalance re-featurized "
    f"{sync['featurize_delta']:.0f} migrated digests")
dev = by["shard/devices"]["metrics"]
print(f"devices={dev['n_devices']:.0f} "
      f"replica spread={dev['distinct_replica_devices']:.0f}")
assert dev["n_devices"] == 8, (
    f"bench saw {dev['n_devices']:.0f} XLA devices — the "
    f"--xla_force_host_platform_device_count=8 flag did not take")
assert dev["distinct_replica_devices"] == 4, \
    "4-replica fleet did not spread over 4 distinct mesh devices"
EOF

echo "== admission-control gate =="
python - <<'EOF'
"""Overload must degrade into *counted* outcomes, never lost requests:
at 2x sustained overload (Poisson arrivals, open loop) every submit
resolves (lost == 0, unaccounted == 0), the bounded queue sheds
(shed > 0 — the high watermark is real), and the served-request p99
stays within 4x the deadline budget (served requests dispatch before
expiry, so p99 ~ deadline + one batch; 4x leaves scheduler-noise
headroom on a saturated CI core) while the unbounded baseline's p99 is
emitted alongside for the trajectory.  The supervision leg: a hung
replica behind the queue is quarantined, its warm rows re-homed, zero
requests lost, and the replica re-admitted after probation — all
asserted inside benchmarks/serving_admission.py, re-checked here to
have landed in the artifact."""
import json

doc = json.load(open("BENCH_10.json"))
by = {r["name"]: r for r in doc["rows"]}
m = by["admission/overload/bounded_p99_ms"]["metrics"]
base = by["admission/overload/unbounded_baseline_p99_ms"]["metrics"]
print(f"admission p99={m['p99_ms']:.0f}ms (deadline {m['deadline_ms']:.0f}ms) "
      f"vs unbounded baseline {base['p99_ms']:.0f}ms "
      f"({base['p99_ratio']:.1f}x); served={m['served']:.0f} "
      f"shed={m['shed']:.0f} deadline_exceeded={m['deadline_exceeded']:.0f} "
      f"lost={m['lost']:.0f}")
assert m["lost"] == 0 and m["unaccounted"] == 0, \
    "overload lost or failed to account for submitted requests"
assert m["shed"] > 0, "2x overload never tripped the high watermark"
assert m["p99_ms"] <= 4.0 * m["deadline_ms"], (
    f"admitted p99 {m['p99_ms']:.0f}ms blew past the deadline budget "
    f"{m['deadline_ms']:.0f}ms (gate: 4x) — the queue is not bounding "
    f"the tail")
sup = by["admission/supervision/lost_requests"]["metrics"]
print(f"supervision: lost={sup['lost']:.0f} "
      f"quarantines={sup['quarantines']:.0f} "
      f"rehomed={sup['rehomed_entries']:.0f} "
      f"readmissions={sup['readmissions']:.0f}")
assert sup["lost"] == 0, "hung-replica scenario lost requests"
assert sup["quarantines"] == 1, "hung replica was never quarantined"
assert sup["readmissions"] == 1 and sup["back_live"] == 1, \
    "quarantined replica never re-admitted after probation"
EOF

if [ "${SMOKE_FAULTS:-0}" = "1" ]; then
  echo "== degraded-mode fault benchmark (standalone, full) =="
  python benchmarks/serving_faults.py
fi

echo "smoke OK"
