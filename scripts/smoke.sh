#!/usr/bin/env bash
# Smoke check: the tier-1 suite plus the serving stack, so the
# pattern -> tuned-kernel fast path (format conversion, autotune cache,
# Pallas SpMM) and the serving engine (batched scoring, plan arena, cache
# persistence) can't silently rot. Run from the repo root:
#   bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== slow stress tests (persistence/arena/threading) =="
python -m pytest -q -m slow

echo "== MoE kernel serving example (engine-driven) =="
python examples/moe_kernel_serving.py

echo "== bsr_preproc benchmark =="
python -m benchmarks.run bsr_preproc

echo "== serving engine benchmark (quick) =="
python benchmarks/serving_engine.py --quick

echo "smoke OK"
