#!/usr/bin/env bash
# Smoke check: the tier-1 suite plus the serving example, so the
# pattern -> tuned-kernel fast path (format conversion, autotune cache,
# Pallas SpMM) can't silently rot. Run from the repo root:
#   bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== MoE kernel serving example =="
python examples/moe_kernel_serving.py

echo "== bsr_preproc benchmark =="
python -m benchmarks.run bsr_preproc

echo "smoke OK"
